"""exp13: fused scan kernel — fused vs unfused warm QPS, roofline
predicted vs realized traffic, serving zero-retrace (ISSUE 10 tentpole,
DESIGN.md §3.9).

Three measurements land in ``BENCH_exp13.json``:

  * ``points`` — warm QPS of the segmented arena scan, ``fused=True`` vs
    ``fused=False``, per (backend, dtype) point at the roofline model's
    tile choice.  The workload is sized so the unfused executor's
    gathered ``[Q, SEG_CHUNK, D]`` intermediate blows the last-level
    cache while the fused tiles stay resident — the regime the fused
    path exists for.  Acceptance: ``speedup ≥ 1.3`` on at least one
    point.  The pallas point runs tiny shapes off-TPU (interpret mode
    executes the kernel body per grid step in Python; its QPS is a
    correctness/count signal there, not a perf number — see
    docs/KERNELS.md).
  * ``roofline`` — per point, the model's predicted bytes/row
    (``launch/roofline.py::scan_bytes_per_row``) against the realized
    effective bytes/row: measured scan seconds × measured host stream
    bandwidth ÷ rows scanned.  Realized ≫ predicted means the schedule
    is re-streaming operands the model assumes are read once (how to
    read this: benchmarks/README.md).
  * ``serving`` — a ``ServingRuntime`` over a ``fused=True`` engine,
    warmed, fed a request wave: ``stats().new_segmented_traces`` must be
    0 (the fused tile model is deterministic per launch signature, so
    warmup covers serving exactly — the §6.3 invariant).

``tiny=True`` shrinks every shape and writes the JSON to a temp dir
unless the caller routes it with ``out_dir`` (the bench-smoke idiom).
"""

from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.launch import roofline

from .common import emit, emit_json, make_dataset


def _segmented_case(n, d, q, lmax, dtype, seed=0):
    """Raw segmented_topk operands: full-span segments (every query scans
    ``lmax`` candidate rows — the QPS denominator is exact)."""
    rng = np.random.default_rng(seed)
    W = 2
    xf = rng.standard_normal((n, d)).astype(np.float32)
    qv = rng.standard_normal((q, d)).astype(np.float32)
    alw = rng.integers(0, 2, (n, W)).astype(np.int32)
    lq = np.zeros((q, W), np.int32)
    lq[:, 0] = 1
    rows = rng.integers(0, n, (q * lmax,)).astype(np.int32)
    starts = (np.arange(q) * lmax).astype(np.int32)
    lens = np.full(q, lmax, np.int32)
    kw = {}
    if dtype == "int8":
        from repro.index.base import quantize_int8
        ax, scale, zero = quantize_int8(xf)
        xd = zero[:, None] + scale[:, None] * ax.astype(np.float32)
        axn = np.sum(xd * xd, axis=1).astype(np.float32)
        kw = dict(scales=jnp.asarray(scale), zeros=jnp.asarray(zero))
    else:
        ax, axn = xf, np.sum(xf * xf, axis=1).astype(np.float32)
    args = (jnp.asarray(qv), jnp.asarray(lq), jnp.asarray(ax),
            jnp.asarray(alw), jnp.asarray(axn), jnp.asarray(rows),
            starts, lens)
    return args, kw


def _time_scan(args, kw, *, k, lmax, backend, dtype, fused, repeats):
    def call():
        jax.block_until_ready(ops.segmented_topk(
            *args, k=k, lmax=lmax, backend=backend, dtype=dtype,
            fused=fused, **kw)[0])
    call()                                     # warm the jit cache
    t0 = time.perf_counter()
    for _ in range(repeats):
        call()
    return (time.perf_counter() - t0) / repeats


def host_stream_bandwidth(nbytes=64 * 2**20, repeats=3) -> float:
    """Measured host copy bandwidth (bytes/s, read+write counted once):
    the denominator that turns scan seconds into effective bytes/row."""
    src = np.ones(nbytes // 8, np.float64)
    dst = np.empty_like(src)
    np.copyto(dst, src)                        # page in both buffers
    t0 = time.perf_counter()
    for _ in range(repeats):
        np.copyto(dst, src)
    dt = (time.perf_counter() - t0) / repeats
    return nbytes / dt


def run(tiny=False, out_dir=None, k=10, repeats=3):
    if out_dir is None:
        out_dir = tempfile.mkdtemp(prefix="exp13_tiny_") if tiny else "."
    # (backend, dtype, shape) points: ref points sized for cache pressure,
    # the pallas point tiny (interpret mode off-TPU)
    if tiny:
        points = [("ref", "f32", dict(n=2000, d=32, q=32, lmax=512)),
                  ("ref", "int8", dict(n=2000, d=32, q=32, lmax=512)),
                  ("pallas", "f32", dict(n=200, d=16, q=2, lmax=16))]
    else:
        points = [("ref", "f32", dict(n=20000, d=64, q=256, lmax=8192)),
                  ("ref", "int8", dict(n=20000, d=64, q=256, lmax=8192)),
                  ("pallas", "f32", dict(n=400, d=16, q=2, lmax=32))]
    bw = host_stream_bandwidth(2**22 if tiny else 64 * 2**20)
    rows_out, payload = [], {"tiny": tiny, "k": k,
                             "host_stream_bw_gbps": bw / 1e9,
                             "points": [], "serving": {}}
    for backend, dtype, shape in points:
        args, kw = _segmented_case(dtype=dtype, **shape)
        lmax, q = shape["lmax"], shape["q"]
        reps = 1 if backend == "pallas" else repeats
        tu = _time_scan(args, kw, k=k, lmax=lmax, backend=backend,
                        dtype=dtype, fused=False, repeats=reps)
        tf = _time_scan(args, kw, k=k, lmax=lmax, backend=backend,
                        dtype=dtype, fused=True, repeats=reps)
        # parity on the measurement inputs (the acceptance's bitwise pin
        # rides along with the perf number)
        fv, fp, fg = ops.segmented_topk(*args, k=k, lmax=lmax,
                                        backend=backend, dtype=dtype,
                                        fused=True, **kw)
        uv, up, ug = ops.segmented_topk(*args, k=k, lmax=lmax,
                                        backend=backend, dtype=dtype,
                                        fused=False, **kw)
        assert np.array_equal(np.asarray(fp), np.asarray(up)), (backend, dtype)
        assert np.array_equal(np.asarray(fg), np.asarray(ug)), (backend, dtype)
        # the ax operand reaches the tile model lane-padded on pallas
        d_seen = 128 if backend == "pallas" else shape["d"]
        tc = roofline.fused_scan_tiles(d_seen, lmax, dtype, q,
                                       backend=backend, label_words=2)
        n_rows = q * lmax
        rec = {
            "backend": backend, "dtype": dtype, **shape,
            "qps_warm_unfused": q / tu, "qps_warm_fused": q / tf,
            "speedup": tu / tf,
            "tiles": {"rows_per_chunk": tc.rows_per_chunk,
                      "queries_per_tile": tc.queries_per_tile,
                      "source": tc.source},
            "roofline": {
                "predicted_bytes_per_row": tc.bytes_per_row,
                "realized_bytes_per_row_fused": tf * bw / n_rows,
                "realized_bytes_per_row_unfused": tu * bw / n_rows,
                "intensity_flops_per_byte": tc.intensity,
            },
        }
        payload["points"].append(rec)
        rows_out.append({
            "name": f"exp13/{backend}_{dtype}",
            "us_per_call": f"{tf / q * 1e6:.1f}",
            "qps_fused": f"{q / tf:.0f}", "qps_unfused": f"{q / tu:.0f}",
            "speedup": f"{tu / tf:.2f}",
            "pred_bytes_row": tc.bytes_per_row,
            "real_bytes_row": f"{tf * bw / n_rows:.0f}"})

    payload["serving"] = _serving_zero_traces(tiny)
    rows_out.append({
        "name": "exp13/serving",
        "us_per_call": "",
        "completed_ok": payload["serving"]["completed_ok"],
        "new_traces": payload["serving"]["new_segmented_traces"]})

    best = max(p["speedup"] for p in payload["points"])
    payload["best_speedup"] = best
    if not tiny:
        # the acceptance bar applies to the recorded artifact; tiny-mode
        # shapes fit in cache, so there is no traffic for fusion to save
        assert best >= 1.3, f"no point reached 1.3x (best {best:.2f})"
    assert payload["serving"]["new_segmented_traces"] == 0

    emit(rows_out, "exp13")
    emit_json(payload, "exp13", out_dir)
    return rows_out


def _serving_zero_traces(tiny: bool) -> dict:
    """ServingRuntime over a fused engine: warm, serve a wave, report the
    post-warmup segmented-trace delta (must be 0)."""
    from repro import arch as A
    from repro.configs import reduced_arch
    from repro.core.engine import LabelHybridEngine
    from repro.models.common import init_params
    from repro.serve import (BatchedDecoder, Request,
                             RetrievalAugmentedEngine, ServingRuntime)

    n = 500 if tiny else 2000
    x, ls, qv, qls = make_dataset(n=n, d=16, n_labels=8, q=16, seed=13)
    spec = reduced_arch("mamba2_130m")
    params = init_params(jax.random.PRNGKey(0), A.param_specs(spec))
    decoder = BatchedDecoder(spec, params, batch_slots=3, max_len=64)
    eli = LabelHybridEngine.build(x, ls, mode="eis", c=0.2, backend="flat",
                                  fused=True)
    rag = RetrievalAugmentedEngine(decoder, eli, k=3, min_bucket=4)
    rt = ServingRuntime(rag, max_coalesce=4, latency_budget_s=0.0,
                        warmup=True)
    rng = np.random.default_rng(5)
    reqs = [Request(prompt=rng.integers(0, spec.cfg.vocab, size=6
                                        ).astype(np.int32),
                    max_new=2, label_set=tuple(qls[i % len(qls)]), rid=i)
            for i in range(8 if tiny else 24)]
    for r in reqs:
        rt.submit(r)
    rt.run_until_idle()
    st = rt.stats()
    rt.assert_no_new_traces()
    return {"requests": len(reqs), "completed_ok": st.completed_ok,
            "retrieval_batches": st.retrieval_batches,
            "new_segmented_traces": st.new_segmented_traces}


if __name__ == "__main__":
    run()
