"""Shared benchmark harness: datasets, timing, recall, CSV emission.

Sizes are scaled to a single CPU core (the paper runs 1M vectors on a
144-thread Xeon); every benchmark keeps the paper's *structure* — same
workloads, same comparisons, same metrics — at reduced N.  The TPU-scale
path is exercised by the dry-run + roofline instead (EXPERIMENTS.md).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import (LabelWorkloadConfig, brute_force_filtered,
                        generate_label_sets, generate_query_label_sets,
                        recall_at_k)
from repro.obs import metrics as obs_metrics


def latency_percentiles(lat_s: list[float]) -> dict:
    """Exact order-statistic percentiles of a pooled latency sample, in
    ms — the single home of the benchmark quantile convention (serving
    benchmarks pool latencies across reps BEFORE taking percentiles;
    a p99 of a single rep is one order statistic of a small sample)."""
    a = np.asarray(lat_s, dtype=np.float64)
    if a.size == 0:
        return {"p50_ms": None, "p99_ms": None, "mean_ms": None,
                "max_ms": None}
    return {
        "p50_ms": float(np.percentile(a, 50) * 1e3),
        "p99_ms": float(np.percentile(a, 99) * 1e3),
        "mean_ms": float(a.mean() * 1e3),
        "max_ms": float(a.max() * 1e3),
    }


def make_dataset(n=20_000, d=32, n_labels=12, q=200, distribution="zipf",
                 seed=0, mean_set_size=3.0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    ls = generate_label_sets(n, LabelWorkloadConfig(
        num_labels=n_labels, distribution=distribution,
        mean_set_size=mean_set_size, seed=seed + 1))
    qv = rng.standard_normal((q, d)).astype(np.float32)
    qls = generate_query_label_sets(ls, q, seed=seed + 2)
    return x, ls, qv, qls


def ground_truth(x, ls, qv, qls, k=10):
    return brute_force_filtered(x, ls, qv, qls, k)


def measure(searcher, qv, qls, k, gt_i, n, repeats=3):
    """(qps, recall, per-query us).  First call warms any jit caches."""
    searcher.search(qv[:4], qls[:4], k)
    t0 = time.perf_counter()
    for _ in range(repeats):
        d, i = searcher.search(qv, qls, k)
    dt = (time.perf_counter() - t0) / repeats
    return (len(qls) / dt, recall_at_k(i, gt_i, n), dt / len(qls) * 1e6)


def measure_modes(eng, qv, qls, k, gt_i, n, repeats=3):
    """Cold/warm QPS for both executors of a LabelHybridEngine.

    Cold = first call of that executor on this engine (routing-table
    warmup plus tracing/compilation of every touched search program not
    already in the process-wide XLA cache — batched runs first, so its
    cold number is the true fresh-engine cost); warm = steady-state mean
    over ``repeats`` — the serving number.  Returns a machine-readable
    dict (see ``emit_json``).
    """
    out = {}
    for mode in ("batched", "looped"):
        fn = getattr(eng, f"search_{mode}")
        t0 = time.perf_counter()
        d, i = fn(qv, qls, k)
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(repeats):
            d, i = fn(qv, qls, k)
        warm = (time.perf_counter() - t0) / repeats
        out[mode] = {
            "cold_s": cold, "warm_s": warm,
            "qps_cold": len(qls) / cold, "qps_warm": len(qls) / warm,
            "us_per_query_warm": warm / len(qls) * 1e6,
            "recall": recall_at_k(i, gt_i, n),
        }
    out["speedup_warm"] = (out["looped"]["warm_s"]
                           / max(out["batched"]["warm_s"], 1e-12))
    return out


def emit_json(payload: dict, name: str, out_dir: str | Path = "."):
    """Write ``BENCH_<name>.json`` — the machine-readable perf artifact
    (CI and later sessions diff these to track the perf trajectory).

    A snapshot of the process-wide metrics registry rides along under a
    ``"metrics"`` key (callers can pre-set the key to override), so every
    benchmark artifact carries the elastic-factor / dispatch / recompile
    accounting of the run that produced it.
    """
    payload = dict(payload)
    if obs_metrics.enabled():
        payload.setdefault("metrics", obs_metrics.snapshot())
    path = Path(out_dir) / f"BENCH_{name}.json"
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"wrote {path}", flush=True)
    return path


def emit(rows: list[dict], name: str):
    """Print one CSV block: name,us_per_call,derived."""
    for r in rows:
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "us_per_call"))
        print(f"{r.get('name', name)},{r.get('us_per_call', '')},{derived}",
              flush=True)
