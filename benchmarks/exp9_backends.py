"""Exp-9 (Table 1 "Index Flexibility" claim): the SAME ELI selection runs
over all four registered index backends — flat (MXU scan), IVF (nprobe
clusters), graph (Vamana beam search), distributed (shard_map scan + top-k
merge) — recall/QPS per backend at fixed c=0.2.  The selection algorithm,
routing, and sub-index membership are identical; only the physical index
changes (paper §1: "not constrained by index type").

Every backend is measured through BOTH executors — the bucketed
jit-cached ``search_batched`` hot path and the per-key ``search_looped``
reference — cold (first call, tracing + compilation included) and warm
(steady state).  The full grid lands in ``BENCH_exp9.json`` so the perf
trajectory is machine-readable across sessions.
"""
from repro.core import LabelHybridEngine

from .common import emit, emit_json, ground_truth, make_dataset, measure_modes

BACKENDS = (
    ("flat", {}),
    ("ivf", {"n_clusters": 32, "nprobe": 8}),
    ("graph", {"M": 12, "ef_search": 64}),
    ("distributed", {}),
)


def run(n=4_000, k=10, out_dir="."):
    x, ls, qv, qls = make_dataset(n=n, n_labels=12, q=80, seed=7)
    gt_d, gt_i = ground_truth(x, ls, qv, qls, k)
    rows, payload = [], {"n": n, "k": k, "q": len(qls), "backends": {}}
    for backend, params in BACKENDS:
        eng = LabelHybridEngine.build(x, ls, mode="eis", c=0.2,
                                      backend=backend, **params)
        modes = measure_modes(eng, qv, qls, k, gt_i, n)
        st = eng.stats()
        payload["backends"][backend] = {
            **modes, "params": params, "n_indexes": st.n_selected,
            "achieved_c": st.achieved_c, "build_seconds": st.build_seconds,
            "nbytes": st.nbytes,
        }
        bat = modes["batched"]
        rows.append({"name": f"exp9/{backend}",
                     "us_per_call": f"{bat['us_per_query_warm']:.1f}",
                     "qps_warm": f"{bat['qps_warm']:.0f}",
                     "qps_cold": f"{bat['qps_cold']:.0f}",
                     "qps_warm_looped": f"{modes['looped']['qps_warm']:.0f}",
                     "speedup_vs_loop": f"{modes['speedup_warm']:.2f}",
                     "recall": f"{bat['recall']:.4f}",
                     "n_indexes": st.n_selected,
                     "achieved_c": f"{st.achieved_c:.3f}"})
    # selection identity: same keys regardless of backend
    emit(rows, "exp9")
    emit_json(payload, "exp9", out_dir)
    return rows


if __name__ == "__main__":
    run()
