"""Exp-9 (Table 1 "Index Flexibility" claim): the SAME ELI selection runs
over all three index backends — flat (MXU scan), IVF (nprobe clusters),
graph (Vamana beam search) — recall/QPS per backend at fixed c=0.2.
The selection algorithm, routing, and sub-index membership are identical;
only the physical index changes (paper §1: "not constrained by index type").
"""
from repro.core.engine import LabelHybridEngine

from .common import emit, ground_truth, make_dataset, measure


def run(n=4_000, k=10):
    x, ls, qv, qls = make_dataset(n=n, n_labels=12, q=80, seed=7)
    gt_d, gt_i = ground_truth(x, ls, qv, qls, k)
    rows = []
    for backend, params in (("flat", {}),
                            ("ivf", {"n_clusters": 32, "nprobe": 8}),
                            ("graph", {"M": 12, "ef_search": 64})):
        eng = LabelHybridEngine.build(x, ls, mode="eis", c=0.2,
                                      backend=backend, **params)
        qps, rec, us = measure(eng, qv, qls, k, gt_i, n)
        st = eng.stats()
        rows.append({"name": f"exp9/{backend}", "us_per_call": f"{us:.1f}",
                     "qps": f"{qps:.0f}", "recall": f"{rec:.4f}",
                     "n_indexes": st.n_selected,
                     "achieved_c": f"{st.achieved_c:.3f}"})
    # selection identity: same keys regardless of backend
    emit(rows, "exp9")
    return rows


if __name__ == "__main__":
    run()
