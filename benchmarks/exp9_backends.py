"""Exp-9 (Table 1 "Index Flexibility" claim): the SAME ELI selection runs
over all four registered index backends — flat (arena-backed segmented
scan), IVF (nprobe clusters), graph (Vamana beam search), distributed
(shard_map scan + top-k merge) — recall/QPS per backend at fixed c=0.2.
The selection algorithm, routing, and sub-index membership are identical;
only the physical index changes (paper §1: "not constrained by index
type").

Three measurements land in ``BENCH_exp9.json``:

  * the executor grid: every backend through BOTH executors — the
    single-dispatch segmented/bucketed ``search_batched`` hot path and the
    per-key ``search_looped`` reference — cold (first call, tracing +
    compilation included) and warm (steady state);
  * ``warmup``: cold-start shrinkage from ``engine.warmup(ks, buckets)``,
    measured in a SUBPROCESS per backend (the XLA executable cache is
    process-wide, so an in-process remeasure would silently be warm) —
    targets the 11.8 s distributed cold batched path recorded pre-arena;
  * ``flat_sweep``: warm QPS of both executors as the selection size grows
    (c sweep) — the arena executor's launches scale with span tiers, not
    with ``n_indexes``, so its warm QPS must stay flat while the per-key
    loop degrades.
"""
import json
import subprocess
import sys
import tempfile

from repro.core import LabelHybridEngine
from repro.index.base import pow2_bucket

from .common import emit, emit_json, ground_truth, make_dataset, measure_modes

BACKENDS = (
    ("flat", {}),
    ("ivf", {"n_clusters": 32, "nprobe": 8}),
    ("graph", {"M": 12, "ef_search": 64}),
    ("distributed", {}),
)

_WARMUP_CHILD = r"""
import json, time
import numpy as np
from benchmarks.common import make_dataset
from benchmarks.exp9_backends import workload_buckets
from repro.core import LabelHybridEngine

backend, params, n, k = json.loads({spec!r})
x, ls, qv, qls = make_dataset(n=n, n_labels=12, q=80, seed=7)
eng = LabelHybridEngine.build(x, ls, mode="eis", c=0.2, backend=backend,
                              **params)
rep = eng.warmup([k], workload_buckets(eng, qls))
t0 = time.perf_counter()
eng.search_batched(qv, qls, k)
cold_after = time.perf_counter() - t0
print("RESULT" + json.dumps({{"warmup_s": rep["seconds"],
                              "programs": rep["programs"],
                              "cold_after_warmup_s": cold_after}}))
"""


def workload_buckets(eng, qls) -> list[int]:
    """The Q-buckets a query workload will induce: per span tier on the
    arena path, per routed group on the private-storage path.  A server
    derives these from its batch-size distribution the same way."""
    routed = eng.route_many(qls)
    counts: dict = {}
    if eng.arena is not None:
        for key in routed:
            lb = pow2_bucket(eng.segments[key][1])
            counts[lb] = counts.get(lb, 0) + 1
    else:
        for key in routed:
            counts[key] = counts.get(key, 0) + 1
    return sorted({pow2_bucket(c) for c in counts.values()})


def _measure_warmup(backend: str, params: dict, n: int, k: int) -> dict:
    spec = json.dumps([backend, params, n, k])
    child = _WARMUP_CHILD.format(spec=spec)
    r = subprocess.run([sys.executable, "-c", child], capture_output=True,
                       text=True, cwd=".")
    line = next((ln for ln in r.stdout.splitlines() if ln.startswith("RESULT")),
                None)
    if line is None:
        print(r.stdout[-2000:], r.stderr[-2000:])
        raise RuntimeError(f"exp9 warmup child failed for {backend}")
    return json.loads(line[len("RESULT"):])


def run(n=4_000, k=10, out_dir=None, measure_warmup=True, sweep=True,
        tiny=False):
    if tiny:
        # CI smoke (benchmarks.run --tiny): all four backends end to end
        # at toy size; subprocess warmup + the sweep are full-size-only
        n, measure_warmup, sweep = 600, False, False
    if out_dir is None:
        # tiny runs must never clobber the recorded artifact unless the
        # caller routed them somewhere explicitly (CI's --out-dir upload)
        out_dir = tempfile.mkdtemp(prefix="exp9_tiny_") if tiny else "."
    x, ls, qv, qls = make_dataset(n=n, n_labels=12, q=80, seed=7)
    gt_d, gt_i = ground_truth(x, ls, qv, qls, k)
    rows, payload = [], {"n": n, "k": k, "q": len(qls), "backends": {}}
    for backend, params in BACKENDS:
        eng = LabelHybridEngine.build(x, ls, mode="eis", c=0.2,
                                      backend=backend, **params)
        modes = measure_modes(eng, qv, qls, k, gt_i, n)
        st = eng.stats()
        payload["backends"][backend] = {
            **modes, "params": params, "n_indexes": st.n_selected,
            "achieved_c": st.achieved_c, "build_seconds": st.build_seconds,
            "nbytes": st.nbytes, "arena_nbytes": st.arena_nbytes,
            "segment_nbytes": st.segment_nbytes,
        }
        if measure_warmup:
            wu = _measure_warmup(backend, params, n, k)
            payload["backends"][backend]["warmup"] = wu
            wu["cold_shrink"] = (modes["batched"]["cold_s"]
                                 / max(wu["cold_after_warmup_s"], 1e-9))
        bat = modes["batched"]
        rows.append({"name": f"exp9/{backend}",
                     "us_per_call": f"{bat['us_per_query_warm']:.1f}",
                     "qps_warm": f"{bat['qps_warm']:.0f}",
                     "qps_cold": f"{bat['qps_cold']:.0f}",
                     "qps_warm_looped": f"{modes['looped']['qps_warm']:.0f}",
                     "speedup_vs_loop": f"{modes['speedup_warm']:.2f}",
                     "recall": f"{bat['recall']:.4f}",
                     "n_indexes": st.n_selected,
                     "achieved_c": f"{st.achieved_c:.3f}"})

    if sweep:
        # selection-size sweep (flat): under the pre-arena executor warm
        # QPS degraded as n_indexes grew (one dispatch per routed group);
        # the segmented executor's launch count is bounded by span tiers
        payload["flat_sweep"] = []
        for c in (0.05, 0.1, 0.2, 0.35, 0.5):
            eng = LabelHybridEngine.build(x, ls, mode="eis", c=c,
                                          backend="flat")
            modes = measure_modes(eng, qv, qls, k, gt_i, n)
            st = eng.stats()
            payload["flat_sweep"].append({
                "c": c, "n_indexes": st.n_selected,
                "qps_warm_batched": modes["batched"]["qps_warm"],
                "qps_warm_looped": modes["looped"]["qps_warm"],
                "speedup_warm": modes["speedup_warm"],
                "nbytes": st.nbytes,
            })
            rows.append({"name": f"exp9/flat_sweep_c={c}",
                         "us_per_call":
                         f"{modes['batched']['us_per_query_warm']:.1f}",
                         "n_indexes": st.n_selected,
                         "qps_warm": f"{modes['batched']['qps_warm']:.0f}",
                         "qps_warm_looped":
                         f"{modes['looped']['qps_warm']:.0f}"})

    # selection identity: same keys regardless of backend
    emit(rows, "exp9")
    emit_json(payload, "exp9", out_dir)
    return rows


if __name__ == "__main__":
    run()
