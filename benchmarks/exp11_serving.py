"""exp11: open-loop serving — continuous-batching runtime vs synchronous
baseline (ROADMAP serving item; the regime of the in-depth filtering
study's throughput/latency tradeoffs).

Offered-load sweep: requests arrive open-loop (Poisson and bursty
processes at matched offered QPS), and we compare

  * **runtime**: ``serve.ServingRuntime`` — bounded admission queue,
    bucket-aware micro-batcher under a latency budget, retrieval
    interleaved with decode, prefills admitted into freed slots;
  * **baseline**: the synchronous ``RetrievalAugmentedEngine.serve()``
    loop — every arrived request batched, retrieval + ``decoder.run()``
    to completion, later arrivals wait for the whole batch (head-of-line
    blocking).

Both systems are warmed identically (``warmup_serving`` + a pilot batch
for the prefill/embed programs), so the curves measure scheduling, not
compilation.  Latency is accounted from the *scheduled* arrival (the
open-loop discipline: queueing delay shows up in p50/p99 instead of
stretching the arrival process).  QPS points scale from a measured
closed-loop capacity estimate so the sweep lands at comparable utilization
on any machine.  → BENCH_exp11.json
"""

from __future__ import annotations

import gc
import time

import jax
import numpy as np

from repro import arch as A
from repro.configs import reduced_arch
from repro.core.engine import LabelHybridEngine
from repro.models.common import init_params
from repro.serve import (
    BatchedDecoder,
    Request,
    RetrievalAugmentedEngine,
    ServingRuntime,
)

from .common import emit, emit_json, latency_percentiles, make_dataset

# long enough that decode dominates service time (the serving regime:
# a synchronous server's head-of-line penalty scales with generation
# length, and the effect has to clear scheduler/OS noise)
MAX_NEW = 12
PROMPT_LENS = (6, 10)


def _make_requests(n, vocab, qls, rng):
    reqs = []
    for i in range(n):
        size = int(rng.choice(PROMPT_LENS))
        prompt = rng.integers(0, vocab, size=size).astype(np.int32)
        ls = tuple(qls[i % len(qls)])
        reqs.append(Request(prompt=prompt, max_new=MAX_NEW, label_set=ls, rid=i))
    return reqs


def _warm_model_programs(rag, vocab, qls, rng, n_req, k):
    """Trace every model-side program either system can dispatch: the
    embed forward per (batch-bucket, seq-bucket) — the runtime's
    micro-batches land on small buckets, the baseline's backlog batches
    on large ones — and the prefill per decode_input length, including
    the short-context lengths a query whose group holds fewer than k
    rows produces (a single unseen length mid-measurement is a
    multi-second XLA compile poisoning that rep's tail).  max_new=1
    requests finish at admission, so most of this never spins the
    decode loop.  Without this the latency curves measure who eats
    which compile, not scheduling."""
    sizes = {1, n_req}
    b = 2
    while b < n_req:
        sizes.add(b)
        b *= 2
    for s in sorted(sizes):
        for ln in PROMPT_LENS:
            batch = []
            for i in range(s):
                prompt = rng.integers(0, vocab, size=ln).astype(np.int32)
                ls = tuple(qls[i % len(qls)])
                batch.append(Request(prompt=prompt, max_new=1, label_set=ls))
            rag.serve(batch)
    dec = rag.decoder
    for ln in PROMPT_LENS:
        for ctx in range(k + 1):
            prompt = rng.integers(0, vocab, size=ln).astype(np.int32)
            req = Request(prompt=prompt, max_new=1)
            req.decode_input = rng.integers(0, vocab, size=ln + ctx).astype(np.int32)
            dec.admit(req)
    dec.step()
    # and the decode-step program (max_new=1 never leaves admission)
    rag.serve(_make_requests(dec.B, vocab, qls, rng))


def poisson_offsets(n, qps, rng):
    return np.cumsum(rng.exponential(1.0 / qps, size=n))


def bursty_offsets(n, qps, rng, burst=8):
    """Bursts of ``burst`` simultaneous arrivals, spaced so the *offered*
    rate matches ``qps`` (the adversarial arrival process for a
    micro-batcher: queue depth spikes per burst)."""
    n_bursts = (n + burst - 1) // burst
    starts = np.arange(n_bursts) * (burst / qps)
    jitter = rng.exponential(0.1 / qps, size=n)
    return np.repeat(starts, burst)[:n] + jitter


def run_baseline(rag, arrivals, max_seconds=300.0, max_batch=64):
    """Synchronous serve loop: batch everything arrived (chunked at the
    warmed ``max_batch`` so a deep backlog stays on pre-traced
    programs), run to completion, repeat.  Returns per-request latency
    from scheduled arrival."""
    t0 = time.monotonic()
    lat = []
    i = 0
    while i < len(arrivals):
        now = time.monotonic() - t0
        batch = []
        while i < len(arrivals) and arrivals[i][0] <= now:
            if len(batch) >= max_batch:
                break
            batch.append(arrivals[i])
            i += 1
        if not batch:
            time.sleep(min(max(arrivals[i][0] - now, 0.0), 1e-3))
            continue
        rag.serve([r for _, r in batch])
        t_done = time.monotonic() - t0
        lat.extend(t_done - t_arr for t_arr, _ in batch)
        if now > max_seconds:
            raise TimeoutError("baseline exceeded time budget")
    return lat


def run_runtime(rag, arrivals, max_coalesce, budget_s):
    rt = ServingRuntime(
        rag,
        queue_depth=4096,
        max_coalesce=max_coalesce,
        latency_budget_s=budget_s,
        warmup=False,
    )
    done = rt.run_open_loop(arrivals)
    rt.assert_no_new_traces()  # the zero-per-request-compilation pin
    return [r.latency for r in done], rt.stats()


def _capacity_estimate(rag, reqs):
    """Closed-loop throughput (req/s) of the synchronous server on a
    pre-generated batch — the yardstick the offered-QPS grid scales
    from."""
    t0 = time.monotonic()
    rag.serve(list(reqs))
    return len(reqs) / (time.monotonic() - t0)


def run(tiny: bool = False, out_dir: str = "."):
    spec = reduced_arch("mamba2_130m")
    params = init_params(jax.random.PRNGKey(0), A.param_specs(spec))
    slots = 4
    dec = BatchedDecoder(spec, params, batch_slots=slots, max_len=64)
    n = 4000 if tiny else 10_000
    x, ls, qv, qls = make_dataset(n=n, d=16, n_labels=10, q=64, seed=11)
    eli = LabelHybridEngine.build(x, ls, mode="eis", c=0.2, backend="flat")
    # the coalesce cap tracks decode capacity: a wider retrieval batch
    # has no amortization to offer once programs are warm — its tail
    # just waits longer in the ready stage for a slot
    rag = RetrievalAugmentedEngine(dec, eli, k=3, min_bucket=4)
    max_coalesce = 2 * slots
    budget_s = 0.002
    rag.warmup_serving(max_batch=64)  # baseline batches can exceed the cap

    rng = np.random.default_rng(17)
    vocab = spec.cfg.vocab
    n_req = 32 if tiny else 240
    reps = 1 if tiny else 5
    _warm_model_programs(rag, vocab, qls, rng, min(n_req, 64), k=3)
    # the capacity run also traces the decode-step program
    cap = _capacity_estimate(rag, _make_requests(64, vocab, qls, rng))
    # the sweep starts where queueing is real: below ~0.6 utilization
    # small-batch service costs put BOTH systems in the same metastable
    # batch-forming regime and the p99 gap is scheduler noise
    utilizations = (0.7,) if tiny else (0.6, 0.8, 0.95)
    processes = {"poisson": poisson_offsets}
    if not tiny:
        processes["bursty"] = bursty_offsets

    results = {
        "capacity_qps_estimate": cap,
        "n_requests": n_req,
        "reps": reps,
        "max_coalesce": max_coalesce,
        "decoder_slots": slots,
        "sweep": {},
    }
    rows = []
    for pname, proc in processes.items():
        for util in utilizations:
            qps = cap * util
            point = {"offered_qps": qps, "utilization": util}
            # reps pool latencies before the percentile: a single
            # open-loop pass's p99 is one order statistic of a queueing
            # process — rep-to-rep variance swamps the systems gap
            pooled = {"baseline": [], "runtime": []}
            gc.collect()
            # a GC pause mid-stream is pure tail noise for either system
            gc.disable()
            for rep in range(reps):
                offs = proc(n_req, qps, np.random.default_rng(23 + rep))
                for system in ("baseline", "runtime"):
                    rng_req = np.random.default_rng(29 + rep)
                    reqs = _make_requests(n_req, vocab, qls, rng_req)
                    arrivals = list(zip(offs.tolist(), reqs))
                    if system == "baseline":
                        lat = run_baseline(rag, arrivals)
                    else:
                        lat, st = run_runtime(rag, arrivals, max_coalesce, budget_s)
                        point["runtime_stats"] = {
                            "batch_size_hist": st.batch_size_hist,
                            "queue_depth_max": st.queue_depth_max,
                            "decode_steps": st.decode_steps,
                            "deadline_misses": st.deadline_misses,
                            "new_segmented_traces": st.new_segmented_traces,
                            # registry-histogram quantiles: coarser than
                            # the pooled exact percentiles below (fixed
                            # buckets, per-runtime-instance) but free at
                            # serve time — the production-side number
                            "latency_p50_s": st.latency_p50_s,
                            "latency_p99_s": st.latency_p99_s,
                        }
                    pooled[system].extend(lat)
            gc.enable()
            for system in ("baseline", "runtime"):
                point[system] = latency_percentiles(pooled[system])
            b99 = point["baseline"]["p99_ms"]
            r99 = point["runtime"]["p99_ms"]
            point["p99_speedup"] = b99 / r99
            results["sweep"][f"{pname}_u{util}"] = point
            row = {
                "name": f"exp11_{pname}_u{util}",
                "us_per_call": r99 * 1e3,
                "offered_qps": round(qps, 1),
                "runtime_p99_ms": round(r99, 2),
                "baseline_p99_ms": round(b99, 2),
                "p99_speedup": round(point["p99_speedup"], 2),
            }
            rows.append(row)
    emit(rows, "exp11")
    emit_json(results, "exp11", out_dir)
    return results


if __name__ == "__main__":
    run()
