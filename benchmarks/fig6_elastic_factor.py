"""Fig 6 — elastic factor directly predicts PostFiltering efficiency.

Queries are grouped by the elastic factor of the index that serves them
(e = |S(L_q)| / |I|); e = 1 is the optimal per-query index.  The paper's
claim: QPS degrades sub-linearly in 1/e (k/c extra accumulation, search
cost still log N).  We reproduce with the Flat backend: the scan cost is
|I|·d exactly, so QPS(e) ~ e·QPS(1) bounds from below — and the measured
curve sits above that bound.
"""
import numpy as np

from repro.core.labels import encode_many, masks_to_int32_words
from repro.index.flat import FlatIndex

from .common import emit, make_dataset, ground_truth, measure


class _Wrap:
    def __init__(self, index, rows, n):
        self.index, self.rows, self.n = index, rows, n

    def search(self, qv, qls, k):
        d, li = self.index.search(
            qv, masks_to_int32_words(encode_many(qls)), k)
        bad = li >= self.rows.size
        gi = np.where(bad, self.n,
                      self.rows[np.clip(li, 0, self.rows.size - 1)])
        return d, gi.astype(np.int32)


def run(n=20_000, k=10):
    x, ls, qv, qls = make_dataset(n=n)
    # query group: the single label whose group is ~5% of N, so every
    # elastic factor down to 0.1 has room to pad (|I| = |S|/e <= N)
    counts = {}
    for s_ in ls:
        for lab_ in s_:
            counts[lab_] = counts.get(lab_, 0) + 1
    lab = min(counts, key=lambda c: abs(counts[c] - 0.05 * n))
    target = (lab,)
    sel = np.array([i for i, s in enumerate(ls) if lab in s], dtype=np.int64)
    qls_fixed = [target] * len(qv)
    gt_d, gt_i = ground_truth(x, ls, qv, qls_fixed, k)
    rows = []
    rng = np.random.default_rng(7)
    words = masks_to_int32_words(encode_many(ls))
    for e in (0.1, 0.2, 0.5, 1.0):
        extra = int(sel.size * (1 - e) / e)
        pool = np.setdiff1d(np.arange(n), sel)
        pad = rng.choice(pool, size=min(extra, pool.size), replace=False)
        member = np.concatenate([sel, pad])
        idx = FlatIndex.build(x[member], words[member])
        qps, rec, us = measure(_Wrap(idx, member, n), qv, qls_fixed, k,
                               gt_i, n)
        rows.append({"name": f"fig6/e={e}", "us_per_call": f"{us:.1f}",
                     "qps": f"{qps:.0f}", "recall": f"{rec:.4f}",
                     "index_size": member.size})
    emit(rows, "fig6")
    return rows


if __name__ == "__main__":
    run()
