"""One function per paper table/figure.  Prints ``name,us_per_call,derived``
CSV.  ``python -m benchmarks.run [--only fig6,exp1,...] [--tiny]
[--tiny-only] [--out-dir DIR]``

``--tiny`` shrinks benchmarks that support it (CI smoke: the bench-smoke
job in .github/workflows/ci.yml runs ``--tiny --tiny-only`` so every
tiny-capable benchmark is exercised end to end per PR); without an
explicit ``--out-dir`` a tiny run writes its JSON artifact to a temp dir,
never over the recorded BENCH_*.json.  ``--tiny-only`` restricts the
selection to benchmarks whose ``run`` accepts a ``tiny`` parameter.
``--out-dir`` routes every produced JSON into one directory (the CI job
uploads it as a workflow artifact for PR-to-PR perf eyeballing).
``--trace`` turns on span tracing and writes one Chrome-trace-event file
``TRACE_<name>.json`` per benchmark next to the JSON artifacts; the
tracer is reset between benchmarks so each file covers exactly one run.
``--metrics`` prints the Prometheus text exposition of the process-wide
registry after the last benchmark."""
import argparse
import inspect
import pathlib
import sys
import time
import traceback

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from . import (exp1_qps_recall, exp2_index_cost, exp3_shard_scaling,
               exp5_distributions, exp6_label_universe, exp7_vs_optimal,
               exp8_adaptive, exp9_backends, exp10_streaming,
               exp11_serving, exp12_durability, exp13_fused_scan,
               fig6_elastic_factor)

ALL = {
    "fig6": fig6_elastic_factor.run,
    "exp1": exp1_qps_recall.run,
    "exp2": exp2_index_cost.run,
    "exp3": exp3_shard_scaling.run,
    "exp5": exp5_distributions.run,
    "exp6": exp6_label_universe.run,
    "exp7": exp7_vs_optimal.run,
    "exp8": exp8_adaptive.run,
    "exp9": exp9_backends.run,
    "exp10": exp10_streaming.run,
    "exp11": exp11_serving.run,
    "exp12": exp12_durability.run,
    "exp13": exp13_fused_scan.run,
}


def tiny_capable(name: str) -> bool:
    return "tiny" in inspect.signature(ALL[name]).parameters


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--tiny-only", action="store_true",
                    help="run only benchmarks that support --tiny")
    ap.add_argument("--out-dir", default="",
                    help="directory for JSON artifacts (benchmarks that "
                         "emit one); created if missing")
    ap.add_argument("--trace", action="store_true",
                    help="enable span tracing; write TRACE_<name>.json "
                         "per benchmark into --out-dir (or cwd)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the Prometheus exposition after all "
                         "benchmarks finish")
    args = ap.parse_args()
    names = [n for n in args.only.split(",") if n] or list(ALL)
    if args.tiny_only:
        names = [n for n in names if tiny_capable(n)]
    if args.out_dir:
        pathlib.Path(args.out_dir).mkdir(parents=True, exist_ok=True)
    trace_dir = pathlib.Path(args.out_dir or ".")
    if args.trace:
        obs_trace.enable()
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        t0 = time.time()
        if args.trace:
            obs_trace.reset()
        try:
            params = inspect.signature(ALL[name]).parameters
            kwargs = {}
            if args.tiny and "tiny" in params:
                kwargs["tiny"] = True
            if args.out_dir and "out_dir" in params:
                kwargs["out_dir"] = args.out_dir
            ALL[name](**kwargs)
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
        if args.trace:
            path = trace_dir / f"TRACE_{name}.json"
            obs_trace.get_tracer().write(path)
            print(f"# wrote {path}", flush=True)
    if args.metrics:
        print(obs_metrics.render(), flush=True)
    if failed:
        print(f"# FAILED: {failed}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
