"""One function per paper table/figure.  Prints ``name,us_per_call,derived``
CSV.  ``python -m benchmarks.run [--only fig6,exp1,...] [--tiny]``

``--tiny`` shrinks benchmarks that support it (CI smoke: exp10 runs this
way from scripts/ci_tier1.sh so the streaming path can't silently rot; a
tiny run writes its JSON artifact to a temp dir, never over the recorded
BENCH_*.json)."""
import argparse
import inspect
import sys
import time
import traceback

from . import (exp1_qps_recall, exp2_index_cost, exp3_shard_scaling,
               exp5_distributions, exp6_label_universe, exp7_vs_optimal,
               exp8_adaptive, exp9_backends, exp10_streaming,
               fig6_elastic_factor)

ALL = {
    "fig6": fig6_elastic_factor.run,
    "exp1": exp1_qps_recall.run,
    "exp2": exp2_index_cost.run,
    "exp3": exp3_shard_scaling.run,
    "exp5": exp5_distributions.run,
    "exp6": exp6_label_universe.run,
    "exp7": exp7_vs_optimal.run,
    "exp8": exp8_adaptive.run,
    "exp9": exp9_backends.run,
    "exp10": exp10_streaming.run,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()
    names = [n for n in args.only.split(",") if n] or list(ALL)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        t0 = time.time()
        try:
            kwargs = {}
            if args.tiny and "tiny" in inspect.signature(
                    ALL[name]).parameters:
                kwargs["tiny"] = True
            ALL[name](**kwargs)
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
