"""Exp-2 (Tables 5/6) — index construction time and size."""
import time

from repro.baselines import BASELINE_REGISTRY
from repro.core.engine import LabelHybridEngine

from .common import emit, make_dataset


def run(n=6_000, L=16):
    x, ls, qv, qls = make_dataset(n=n, n_labels=L, q=8)
    rows = []
    t0 = time.perf_counter()
    eng = LabelHybridEngine.build(x, ls, mode="eis", c=0.2, backend="flat")
    st = eng.stats()
    rows.append({"name": "exp2/ELI-0.2", "us_per_call": "",
                 "build_s": f"{time.perf_counter() - t0:.2f}",
                 "select_s": f"{st.select_seconds:.3f}",
                 "entries": st.total_entries, "mb": f"{st.nbytes/2**20:.1f}",
                 "n_indexes": st.n_selected,
                 "achieved_c": f"{st.achieved_c:.3f}"})
    t0 = time.perf_counter()
    eng2 = LabelHybridEngine.build(x, ls, mode="sis", space_budget=2 * n,
                                   backend="flat")
    st2 = eng2.stats()
    rows.append({"name": "exp2/ELI-2.0", "us_per_call": "",
                 "build_s": f"{time.perf_counter() - t0:.2f}",
                 "entries": st2.total_entries,
                 "mb": f"{st2.nbytes/2**20:.1f}",
                 "achieved_c": f"{st2.achieved_c:.3f}"})
    for bname in ("postfilter", "acorn1", "acorn_gamma", "ung", "optimal"):
        t0 = time.perf_counter()
        b = BASELINE_REGISTRY[bname](x, ls)
        rows.append({"name": f"exp2/{bname}", "us_per_call": "",
                     "build_s": f"{time.perf_counter() - t0:.2f}",
                     "mb": f"{b.nbytes/2**20:.1f}"})
    emit(rows, "exp2")
    return rows


if __name__ == "__main__":
    run()
