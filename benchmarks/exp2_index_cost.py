"""Exp-2 (Tables 5/6) — index construction time and size.

Emits machine-readable ``BENCH_exp2.json`` (via ``common.emit_json``) so
the arena's memory/build-time win is recorded in the perf trajectory
alongside ``BENCH_exp9.json``: per engine we log build/select seconds,
stored entries, and the nbytes split (shared arena + CSR segment table vs
per-index private storage — see ``EngineStats``).

The ``storage_frontier`` section sweeps the tiered-precision arena
(DESIGN.md §3.8) over every storage spec — ``f32``, ``fp16``, ``int8``,
``fp16+rerank``, ``int8+rerank`` — at the executor's default k′ = 4k
shortlist, recording the arena bytes/row-vs-recall@10 frontier on the
10k/500 fixture.  The acceptance bar pinned here: the rerank-free int8
tier holds recall@10 ≥ 0.99 at ≥ 2× bytes/row reduction over f32.
"""
import tempfile
import time

from repro.baselines import BASELINE_REGISTRY
from repro.core import recall_at_k
from repro.core.engine import LabelHybridEngine

from .common import emit, emit_json, ground_truth, make_dataset

STORAGE_SPECS = ("f32", "fp16", "int8", "fp16+rerank", "int8+rerank")


def _eli_row(name: str, eng, wall_s: float) -> tuple[dict, dict]:
    st = eng.stats()
    row = {"name": f"exp2/{name}", "us_per_call": "",
           "build_s": f"{wall_s:.2f}",
           "select_s": f"{st.select_seconds:.3f}",
           "entries": st.total_entries, "mb": f"{st.nbytes/2**20:.1f}",
           "n_indexes": st.n_selected,
           "achieved_c": f"{st.achieved_c:.3f}"}
    payload = {"wall_s": wall_s, "select_s": st.select_seconds,
               "index_build_s": st.build_seconds,
               "entries": st.total_entries, "n_indexes": st.n_selected,
               "achieved_c": st.achieved_c, "nbytes": st.nbytes,
               "arena_nbytes": st.arena_nbytes,
               "segment_nbytes": st.segment_nbytes}
    return row, payload


def _storage_frontier(rows: list, payload: dict, tiny: bool) -> None:
    """Arena bytes/row vs recall@10 across the five storage specs."""
    n, q = (1_500, 60) if tiny else (10_000, 500)
    x, ls, qv, qls = make_dataset(n=n, d=32, q=q)
    _, gt_i = ground_truth(x, ls, qv, qls, k=10)
    frontier = {}
    f32_bpr = None
    for spec in STORAGE_SPECS:
        t0 = time.perf_counter()
        eng = LabelHybridEngine.build(x, ls, mode="eis", c=0.2,
                                      backend="flat", storage=spec)
        build_s = time.perf_counter() - t0
        _, ids = eng.search_batched(qv, qls, 10)   # default k′ = 4k
        rec = recall_at_k(ids, gt_i, n)
        st = eng.stats()
        bpr = st.arena_nbytes / n
        if spec == "f32":
            f32_bpr = bpr
        red = f32_bpr / bpr
        frontier[spec] = {
            "bytes_per_row": bpr, "recall_at_10": rec,
            "reduction_vs_f32": red, "build_s": build_s,
            "arena_nbytes": st.arena_nbytes,
            "codes_nbytes": st.codes_nbytes,
            "scales_nbytes": st.scales_nbytes,
            "rerank_nbytes": st.rerank_nbytes,
        }
        rows.append({"name": f"exp2/storage-{spec}", "us_per_call": "",
                     "bytes_per_row": f"{bpr:.1f}",
                     "recall_at_10": f"{rec:.4f}",
                     "reduction_vs_f32": f"{red:.2f}x"})
    payload["storage_frontier"] = {"n": n, "q": q, "k": 10,
                                   "kprime": "4k", "specs": frontier}


def run(n=6_000, L=16, out_dir=None, tiny=False):
    if tiny:
        # CI smoke: engines + every baseline still build end to end
        n, L = 800, 8
    if out_dir is None:
        out_dir = tempfile.mkdtemp(prefix="exp2_tiny_") if tiny else "."
    x, ls, qv, qls = make_dataset(n=n, n_labels=L, q=8)
    rows, payload = [], {"n": n, "n_labels": L, "engines": {},
                         "baselines": {}}
    t0 = time.perf_counter()
    eng = LabelHybridEngine.build(x, ls, mode="eis", c=0.2, backend="flat")
    row, p = _eli_row("ELI-0.2", eng, time.perf_counter() - t0)
    rows.append(row)
    payload["engines"]["ELI-0.2"] = p

    t0 = time.perf_counter()
    eng2 = LabelHybridEngine.build(x, ls, mode="sis", space_budget=2 * n,
                                   backend="flat")
    row, p = _eli_row("ELI-2.0", eng2, time.perf_counter() - t0)
    rows.append(row)
    payload["engines"]["ELI-2.0"] = p

    for bname in ("postfilter", "acorn1", "acorn_gamma", "ung", "optimal"):
        t0 = time.perf_counter()
        b = BASELINE_REGISTRY[bname](x, ls)
        dt = time.perf_counter() - t0
        rows.append({"name": f"exp2/{bname}", "us_per_call": "",
                     "build_s": f"{dt:.2f}", "mb": f"{b.nbytes/2**20:.1f}"})
        payload["baselines"][bname] = {"build_s": dt, "nbytes": b.nbytes}
    _storage_frontier(rows, payload, tiny)
    emit(rows, "exp2")
    emit_json(payload, "exp2", out_dir)
    return rows


if __name__ == "__main__":
    run()
