"""Exp-10 (ISSUE 4 + ISSUE 5): the streaming mutation subsystem under load.

Four measurements land in ``BENCH_exp10.json``:

  * ``fill_sweep`` — warm QPS + recall of ``StreamingEngine.search_batched``
    as the delta arena fills (0% → 20% of the base), against the static
    engine's warm QPS on the same (grown) dataset and against exact
    ground truth over the CURRENT survivors.  The acceptance bar: at 10%
    delta fill warm QPS stays within 1.5× of the static engine
    (``qps_ratio_static`` ≤ 1.5 in inverse form: streaming ≥ static/1.5).
  * ``compaction`` — latency of ``flush()`` (device-side arena fold +
    incremental GroupTable + kept-keys apply_selection) vs a full
    ``LabelHybridEngine.build`` from scratch on the survivors
    (re-grouping, re-selection, host re-upload).  ``speedup_vs_rebuild``
    is the acceptance's "compaction ≫ faster than full rebuild".
  * ``warmup`` — cold-start shrinkage of the FIRST post-insert batch after
    ``StreamingEngine.warmup`` pre-traced the tombstone-fused base, delta
    -scan, and merge programs — measured in a SUBPROCESS (the exp9
    pattern: the XLA executable cache is process-wide, an in-process
    remeasure would silently be warm).
  * ``delete_sweep`` (ISSUE 5) — a delete-heavy workload (delete batch →
    search batch, repeated) on PRIVATE-storage backends, lazy tombstones
    (``lazy_deletes=True``, the default: per-index bitmaps through
    ``search_padded(tomb=…)``) vs the PR 4 fold-per-delete path
    (``lazy_deletes=False``: every delete forces a full seeded rebuild at
    the next search).  ``lazy_speedup`` is the acceptance bar: delete
    latency drops from O(build) to O(n/8) host bytes, so lazy must win by
    a wide margin.

``tiny=True`` (the ci_tier1 smoke) shrinks sizes and writes the JSON to a
temp dir (unless the caller routes it with an explicit ``out_dir`` — the
CI bench-smoke job uploads that directory as a workflow artifact) so a
smoke run never clobbers the recorded perf artifact.
"""
import json
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.core import LabelHybridEngine, LabelWorkloadConfig, StreamingEngine
from repro.core import generate_label_sets
from repro.index.base import pow2_bucket

from .common import emit, emit_json, ground_truth, make_dataset

_WARMUP_CHILD = r"""
import json, time
import numpy as np
from benchmarks.common import make_dataset
from benchmarks.exp10_streaming import insert_pool
from repro.core import StreamingEngine
from repro.index.base import pow2_bucket

n, k, q, warm = json.loads({spec!r})
x, ls, qv, qls = make_dataset(n=n, n_labels=12, q=q, seed=7)
px, pls = insert_pool(n // 10, x.shape[1], seed=29)
se = StreamingEngine.build(x, ls, mode="eis", c=0.2, backend="flat",
                           max_delta_fraction=None,
                           max_tombstone_fraction=None,
                           min_delta_capacity=pow2_bucket(n // 10))
warmup_s, programs = 0.0, 0
if warm:
    rep = se.warmup([k], [pow2_bucket(q)])
    warmup_s, programs = rep["seconds"], rep["programs"]
se.insert(px, pls)                       # first mutation AFTER warmup
se.delete(np.arange(0, n, 97))
t0 = time.perf_counter()
se.search_batched(qv, qls, k, min_bucket=pow2_bucket(q))
cold_after = time.perf_counter() - t0
print("RESULT" + json.dumps({{"warmup_s": warmup_s, "programs": programs,
                              "first_mutated_batch_s": cold_after}}))
"""


def insert_pool(m: int, d: int, seed: int = 29):
    """Held-out rows to stream in (same label universe as the base)."""
    rng = np.random.default_rng(seed)
    px = rng.standard_normal((m, d)).astype(np.float32)
    pls = generate_label_sets(m, LabelWorkloadConfig(num_labels=12,
                                                     seed=seed + 1))
    return px, pls


def _delete_heavy_sweep(x, ls, qv, qls, k, backends, batches, batch_rows):
    """Interleaved delete-batch → search-batch loop per private backend,
    lazy tombstones vs fold-per-delete (both warmed before timing; the
    fold mode's warm state is immediately invalidated by the first
    delete, which is exactly the cost being measured)."""
    out = {}
    for backend, params in backends:
        res = {}
        for mode, lazy in (("lazy", True), ("fold_per_delete", False)):
            se = StreamingEngine.build(
                x, ls, mode="eis", c=0.2, backend=backend,
                max_delta_fraction=None, max_tombstone_fraction=None,
                lazy_deletes=lazy, **params)
            se.search_batched(qv, qls, k)            # warm the caches
            remaining = np.random.default_rng(17).permutation(
                len(ls)).astype(np.int64)
            folds_seen = 0
            t0 = time.perf_counter()
            for _ in range(batches):
                batch = remaining[:batch_rows]
                remaining = remaining[batch_rows:]
                se.delete(batch)
                se.search_batched(qv, qls, k)
                # the fold path renumbers survivors at every fold, so
                # future victims must translate through each id_map — an
                # API-visible cost of fold-per-delete the lazy path does
                # not impose (ids stay stable between compactions)
                while folds_seen < len(se.compaction_log):
                    id_map = se.compaction_log[folds_seen]["id_map"]
                    folds_seen += 1
                    remaining = id_map[remaining]
                    remaining = remaining[remaining >= 0]
            dt = time.perf_counter() - t0
            res[mode] = {"seconds": dt,
                         "qps": batches * len(qls) / dt,
                         "deleted_rows": batches * batch_rows}
            assert se.lazy_deletes_active == lazy
            assert se.stats().live_rows == len(ls) - batches * batch_rows
        res["lazy_speedup"] = (res["fold_per_delete"]["seconds"]
                               / max(res["lazy"]["seconds"], 1e-9))
        out[backend] = res
    return out


def _measure_qps(searcher, qv, qls, k, repeats=3):
    searcher.search_batched(qv, qls, k)          # warm the caches
    t0 = time.perf_counter()
    for _ in range(repeats):
        d, i = searcher.search_batched(qv, qls, k)
    warm = (time.perf_counter() - t0) / repeats
    return len(qls) / warm, (d, i)


def _measure_warmup(n: int, k: int, q: int, warm: bool) -> dict:
    spec = json.dumps([n, k, q, warm])
    child = _WARMUP_CHILD.format(spec=spec)
    r = subprocess.run([sys.executable, "-c", child], capture_output=True,
                       text=True, cwd=".")
    line = next((ln for ln in r.stdout.splitlines() if ln.startswith("RESULT")),
                None)
    if line is None:
        print(r.stdout[-2000:], r.stderr[-2000:])
        raise RuntimeError("exp10 warmup child failed")
    return json.loads(line[len("RESULT"):])


def run(n=4_000, k=10, out_dir=None, measure_warmup=True, tiny=False):
    if tiny:
        n, measure_warmup = 600, True
    if out_dir is None:
        out_dir = tempfile.mkdtemp(prefix="exp10_tiny_") if tiny else "."
    q = 80
    x, ls, qv, qls = make_dataset(n=n, n_labels=12, q=q, seed=7)
    pool_m = n // 5 + 8
    px, pls = insert_pool(pool_m, x.shape[1], seed=29)
    rows, payload = [], {"n": n, "k": k, "q": q, "tiny": tiny,
                         "fill_sweep": [], "deleted": {}, "compaction": {},
                         "delete_sweep": {}}

    # -- fill sweep: streaming (delta pending) vs static on the same rows --
    for fill in (0.0, 0.05, 0.10, 0.20):
        m = int(round(fill * n))
        se = StreamingEngine.build(x, ls, mode="eis", c=0.2, backend="flat",
                                   max_delta_fraction=None,
                                   max_tombstone_fraction=None,
                                   min_delta_capacity=pow2_bucket(max(m, 1)))
        if m:
            se.insert(px[:m], pls[:m])
        grown_x = np.concatenate([x, px[:m]])
        grown_ls = list(ls) + list(pls[:m])
        static = LabelHybridEngine.build(grown_x, grown_ls, mode="eis",
                                         c=0.2, backend="flat")
        gt_d, gt_i = ground_truth(grown_x, grown_ls, qv, qls, k)
        qps_stream, (d_s, i_s) = _measure_qps(se, qv, qls, k)
        qps_static, (d_t, i_t) = _measure_qps(static, qv, qls, k)
        from repro.core import recall_at_k
        rec = {"fill": fill, "delta_rows": m,
               "qps_warm_streaming": qps_stream,
               "qps_warm_static": qps_static,
               "static_over_streaming": qps_static / max(qps_stream, 1e-9),
               "recall_streaming": recall_at_k(i_s, gt_i, len(grown_ls)),
               "recall_static": recall_at_k(i_t, gt_i, len(grown_ls))}
        payload["fill_sweep"].append(rec)
        rows.append({"name": f"exp10/fill={fill}",
                     "us_per_call": f"{1e6 / max(qps_stream, 1e-9):.1f}",
                     "qps_warm": f"{qps_stream:.0f}",
                     "qps_warm_static": f"{qps_static:.0f}",
                     "slowdown": f"{rec['static_over_streaming']:.2f}",
                     "recall": f"{rec['recall_streaming']:.4f}"})

    # -- tombstones: 10% deleted, searched through the fused mask ----------
    se = StreamingEngine.build(x, ls, mode="eis", c=0.2, backend="flat",
                               max_delta_fraction=None,
                               max_tombstone_fraction=None)
    rng = np.random.default_rng(31)
    dead = rng.choice(n, n // 10, replace=False)
    se.delete(dead)
    alive = np.setdiff1d(np.arange(n), dead)
    gt_d, gt_i = ground_truth(x[alive], [ls[i] for i in alive], qv, qls, k)
    qps_tomb, (d_s, i_s) = _measure_qps(se, qv, qls, k)
    from repro.core import recall_at_k
    id_back = np.full(n + 1, len(alive), np.int64)
    id_back[alive] = np.arange(len(alive))
    i_mapped = np.where(i_s < n, id_back[np.clip(i_s, 0, n)], len(alive))
    payload["deleted"] = {
        "fraction": 0.10, "qps_warm": qps_tomb,
        "recall": recall_at_k(i_mapped, gt_i, len(alive))}

    # -- compaction vs full rebuild (same survivors + pending inserts) -----
    m = n // 10
    se.insert(px[:m], pls[:m])
    surv_x = np.concatenate([x[alive], px[:m]])
    surv_ls = [ls[i] for i in alive] + list(pls[:m])
    rep = se.flush()
    compact_s = rep["seconds"]
    t0 = time.perf_counter()
    LabelHybridEngine.build(surv_x, surv_ls, mode="eis", c=0.2,
                            backend="flat")
    rebuild_s = time.perf_counter() - t0
    payload["compaction"] = {
        "folded_rows": rep["folded_rows"], "dropped_rows": rep["dropped_rows"],
        "compact_s": compact_s, "full_rebuild_s": rebuild_s,
        "speedup_vs_rebuild": rebuild_s / max(compact_s, 1e-9)}
    rows.append({"name": "exp10/compaction",
                 "us_per_call": f"{compact_s * 1e6:.0f}",
                 "full_rebuild_us": f"{rebuild_s * 1e6:.0f}",
                 "speedup_vs_rebuild":
                 f"{payload['compaction']['speedup_vs_rebuild']:.1f}"})

    # -- delete-heavy: lazy tombstones vs fold-per-delete (ISSUE 5) --------
    # graph is omitted from the timed sweep (its Vamana fold is so slow the
    # comparison is a foregone conclusion — it takes the identical lazy
    # path); ivf exercises the wave-widening mask, distributed the sharded
    # bitmap + collective merge
    sweep_backends = [("ivf", {"nprobe": 8})]
    if not tiny:
        sweep_backends.append(("distributed", {}))
    payload["delete_sweep"] = _delete_heavy_sweep(
        x, ls, qv, qls, k, sweep_backends,
        batches=3 if tiny else 6, batch_rows=max(n // 50, 1))
    for backend, res in payload["delete_sweep"].items():
        rows.append({"name": f"exp10/deletes_{backend}",
                     "us_per_call": f"{1e6 / max(res['lazy']['qps'], 1e-9):.1f}",
                     "qps_lazy": f"{res['lazy']['qps']:.0f}",
                     "qps_fold": f"{res['fold_per_delete']['qps']:.0f}",
                     "lazy_speedup": f"{res['lazy_speedup']:.1f}"})

    # -- warmup: first post-insert batch, subprocess-isolated --------------
    if measure_warmup:
        wu = _measure_warmup(n, k, q, warm=True)
        nowu = _measure_warmup(n, k, q, warm=False)
        wu["first_mutated_batch_unwarmed_s"] = nowu["first_mutated_batch_s"]
        wu["cold_shrink"] = (nowu["first_mutated_batch_s"]
                             / max(wu["first_mutated_batch_s"], 1e-9))
        payload["warmup"] = wu
        rows.append({"name": "exp10/warmup",
                     "us_per_call": f"{wu['first_mutated_batch_s']*1e6:.0f}",
                     "unwarmed_us":
                     f"{wu['first_mutated_batch_unwarmed_s']*1e6:.0f}",
                     "cold_shrink": f"{wu['cold_shrink']:.1f}",
                     "programs": wu["programs"]})

    emit(rows, "exp10")
    emit_json(payload, "exp10", out_dir)
    return rows


if __name__ == "__main__":
    run()
