"""Exp-1 (Fig 10) — QPS/recall tradeoff: ELI-0.2 and ELI-2.0 vs the
baseline field (pre/post-filter, ACORN-1/γ, UNG, NHQ) across |L|."""
from repro.baselines import BASELINE_REGISTRY
from repro.core.engine import LabelHybridEngine

from .common import emit, ground_truth, make_dataset, measure


def run(n=6_000, k=10, label_sizes=(8, 16)):
    rows = []
    for L in label_sizes:
        x, ls, qv, qls = make_dataset(n=n, n_labels=L, q=120)
        gt_d, gt_i = ground_truth(x, ls, qv, qls, k)
        engines = {
            "ELI-0.2": LabelHybridEngine.build(x, ls, mode="eis", c=0.2,
                                               backend="flat"),
            "ELI-2.0": LabelHybridEngine.build(x, ls, mode="sis",
                                               space_budget=2 * n,
                                               backend="flat"),
        }
        for bname in ("prefilter", "postfilter", "acorn1", "acorn_gamma",
                      "ung", "nhq"):
            engines[bname] = BASELINE_REGISTRY[bname](x, ls)
        for name, eng in engines.items():
            qps, rec, us = measure(eng, qv, qls, k, gt_i, n)
            rows.append({"name": f"exp1/L={L}/{name}",
                         "us_per_call": f"{us:.1f}",
                         "qps": f"{qps:.0f}", "recall": f"{rec:.4f}"})
    emit(rows, "exp1")
    return rows


if __name__ == "__main__":
    run()
