"""Exp-1 (Fig 10) — QPS/recall tradeoff: ELI-0.2 and ELI-2.0 vs the
baseline field (pre/post-filter, ACORN-1/γ, UNG, NHQ) across |L|.

The ELI rows run through the batched multi-index executor (the default
search path); ``*-loop`` rows re-measure the same engine through the
per-key reference loop so the executor's QPS win is visible in the CSV.
"""
from repro.baselines import BASELINE_REGISTRY
from repro.core.engine import LabelHybridEngine

from .common import emit, ground_truth, make_dataset, measure


class _LoopPath:
    """Adapter exposing the per-key reference loop as a searcher."""

    def __init__(self, engine: LabelHybridEngine):
        self._engine = engine

    def search(self, queries, query_label_sets, k):
        return self._engine.search_looped(queries, query_label_sets, k)


def run(n=6_000, k=10, label_sizes=(8, 16)):
    rows = []
    for L in label_sizes:
        x, ls, qv, qls = make_dataset(n=n, n_labels=L, q=120)
        gt_d, gt_i = ground_truth(x, ls, qv, qls, k)
        eli_02 = LabelHybridEngine.build(x, ls, mode="eis", c=0.2,
                                         backend="flat")
        eli_20 = LabelHybridEngine.build(x, ls, mode="sis",
                                         space_budget=2 * n,
                                         backend="flat")
        engines = {
            "ELI-0.2": eli_02,
            "ELI-0.2-loop": _LoopPath(eli_02),
            "ELI-2.0": eli_20,
            "ELI-2.0-loop": _LoopPath(eli_20),
        }
        for bname in ("prefilter", "postfilter", "acorn1", "acorn_gamma",
                      "ung", "nhq"):
            engines[bname] = BASELINE_REGISTRY[bname](x, ls)
        for name, eng in engines.items():
            qps, rec, us = measure(eng, qv, qls, k, gt_i, n)
            rows.append({"name": f"exp1/L={L}/{name}",
                         "us_per_call": f"{us:.1f}",
                         "qps": f"{qps:.0f}", "recall": f"{rec:.4f}"})
    emit(rows, "exp1")
    return rows


if __name__ == "__main__":
    run()
