"""Exp-12 (ISSUE 8): what crash consistency costs, and what it buys.

Three measurements land in ``BENCH_exp12.json``:

  * ``insert_qps`` — streamed insert throughput (batches of 256 rows —
    one fsync per batch; at this disk's ~0.6 ms fsync latency smaller
    batches measure the disk, not the log) three ways: plain
    ``StreamingEngine`` (no durability), WAL-enabled
    ``DurableStreamingEngine`` with ``fsync=True`` (the crash-safe
    configuration: every batch is checksummed, appended, and fsynced
    before it is applied), and ``fsync=False`` (ack-on-page-cache, the
    middle ground).  The acceptance bar: zero-fault WAL-enabled insert
    stays within 1.5× of the non-WAL path (``wal_overhead`` ≤ 1.5) —
    log-first durability must ride the mutation stream, not throttle it.
  * ``snapshot`` — published snapshot bytes vs the live arena device
    bytes it restores (the snapshot stores host mirrors + staged state;
    quantized tiers re-encode deterministically on restore, so they are
    not persisted twice).
  * ``recovery`` — time of ``recover()`` (newest snapshot + WAL-tail
    replay) vs the no-durability alternative: rebuild from the original
    dataset and re-apply every mutation from scratch.  Both paths pay a
    deterministic base build (device state is rebuilt, not mmapped —
    DESIGN.md §5 "replayed vs rebuilt"), so the win comes from the
    history the snapshot absorbed: the compaction folded before the
    snapshot is replayed by the rebuild path but NOT by recovery, and
    the margin grows with the mutation history.

``tiny=True`` (the ci_tier1 smoke / bench-smoke job) shrinks sizes and
writes the JSON to a temp dir unless the caller routes it with an
explicit ``out_dir``, so a smoke run never clobbers the recorded perf
artifact.
"""
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import StreamingEngine
from repro.core.durability import DurableStreamingEngine, recover
from repro.index.base import pow2_bucket

from .common import emit, emit_json, make_dataset
from .exp10_streaming import insert_pool


def _dir_bytes(path: Path) -> int:
    return sum(f.stat().st_size for f in Path(path).rglob("*")
               if f.is_file())


def _time_inserts(eng, px, pls, batch: int) -> float:
    t0 = time.perf_counter()
    for i in range(0, len(px), batch):
        eng.insert(px[i:i + batch], pls[i:i + batch])
    return time.perf_counter() - t0


# Each variant is rebuilt + re-timed this many times and the best pass
# is recorded: single fsyncs on this filesystem spike 0.6→2 ms, and one
# spike inside a dozen-batch window would otherwise decide the ratio.
REPEATS = 3


def run(n=4_000, k=10, out_dir=None, tiny=False):
    if tiny:
        n = 600
    if out_dir is None:
        out_dir = tempfile.mkdtemp(prefix="exp12_tiny_") if tiny else "."
    q = 40
    batch = 256
    batches = 4 if tiny else 12
    m = batch * batches
    x, ls, qv, qls = make_dataset(n=n, n_labels=12, q=q, seed=9)
    px, pls = insert_pool(m + batch, x.shape[1], seed=33)
    kw = dict(mode="eis", c=0.2, backend="flat",
              max_delta_fraction=None, max_tombstone_fraction=None,
              min_delta_capacity=pow2_bucket(m + batch))
    rows, payload = [], {"n": n, "k": k, "insert_batch": batch,
                         "insert_batches": batches, "tiny": tiny}

    # -- insert QPS: plain vs WAL (fsync on/off), zero faults injected ----
    variants = {}
    for rep in range(REPEATS):
        se = StreamingEngine.build(x, ls, **kw)
        se.insert(px[m:], pls[m:])               # warm the append programs
        s = _time_inserts(se, px[:m], pls[:m], batch)
        variants["plain"] = min(variants.get("plain", s), s)
    for name, fsync in (("wal_fsync", True), ("wal_nofsync", False)):
        for rep in range(REPEATS):
            with tempfile.TemporaryDirectory() as d:
                eng = DurableStreamingEngine.build(x, ls, Path(d) / "dur",
                                                   fsync=fsync, **kw)
                eng.insert(px[m:], pls[m:])      # warm
                s = _time_inserts(eng, px[:m], pls[:m], batch)
                variants[name] = min(variants.get(name, s), s)
                eng.close()
    payload["insert_qps"] = {
        name: {"seconds": s, "rows_per_s": m / max(s, 1e-9)}
        for name, s in variants.items()}
    overhead = variants["wal_fsync"] / max(variants["plain"], 1e-9)
    payload["insert_qps"]["wal_overhead"] = overhead
    payload["insert_qps"]["within_1p5x"] = bool(overhead <= 1.5)
    rows.append({"name": "exp12/insert_wal",
                 "us_per_call": f"{variants['wal_fsync'] / batches * 1e6:.0f}",
                 "rows_per_s_plain": f"{m / variants['plain']:.0f}",
                 "rows_per_s_wal": f"{m / variants['wal_fsync']:.0f}",
                 "wal_overhead": f"{overhead:.2f}"})

    # -- snapshot bytes vs arena bytes + recovery vs rebuild --------------
    with tempfile.TemporaryDirectory() as d:
        dur = Path(d) / "dur"
        eng = DurableStreamingEngine.build(x, ls, dur, **kw)
        for i in range(0, m // 2, batch):        # pre-snapshot mutations
            eng.insert(px[i:i + batch], pls[i:i + batch])
        eng.delete(np.arange(0, n, 61, dtype=np.int64))
        eng.flush()          # the snapshot persists the COMPACTED state
        t0 = time.perf_counter()
        snap = eng.snapshot()
        snapshot_s = time.perf_counter() - t0
        arena_bytes = eng.engine.base.arena.nbytes + eng.engine.delta.nbytes
        payload["snapshot"] = {
            "snapshot_bytes": _dir_bytes(snap),
            "arena_bytes": int(arena_bytes),
            "snapshot_s": snapshot_s,
            "bytes_ratio": _dir_bytes(snap) / max(arena_bytes, 1)}
        for i in range(m // 2, m, batch):        # the WAL tail to replay
            eng.insert(px[i:i + batch], pls[i:i + batch])
        eng.delete(np.arange(1, n, 97, dtype=np.int64))
        want = eng.search_batched(qv, qls, k)
        wal_bytes = (dur / "wal.log").stat().st_size
        eng.close()

        t0 = time.perf_counter()
        rec = recover(dur)
        recover_s = time.perf_counter() - t0
        got = rec.search_batched(qv, qls, k)
        assert np.array_equal(np.asarray(want[1]), np.asarray(got[1]))
        rec.close()

        # the no-durability alternative: rebuild from the original data
        # and re-apply every mutation from scratch
        t0 = time.perf_counter()
        sv = StreamingEngine.build(x, ls, **kw)
        for i in range(0, m // 2, batch):
            sv.insert(px[i:i + batch], pls[i:i + batch])
        sv.delete(np.arange(0, n, 61, dtype=np.int64))
        sv.flush()
        for i in range(m // 2, m, batch):
            sv.insert(px[i:i + batch], pls[i:i + batch])
        sv.delete(np.arange(1, n, 97, dtype=np.int64))
        rebuild_s = time.perf_counter() - t0
    payload["recovery"] = {
        "recover_s": recover_s, "full_rebuild_s": rebuild_s,
        "wal_tail_bytes": int(wal_bytes),
        "speedup_vs_rebuild": rebuild_s / max(recover_s, 1e-9)}
    rows.append({"name": "exp12/recovery",
                 "us_per_call": f"{recover_s * 1e6:.0f}",
                 "full_rebuild_us": f"{rebuild_s * 1e6:.0f}",
                 "speedup_vs_rebuild":
                 f"{payload['recovery']['speedup_vs_rebuild']:.2f}",
                 "snapshot_mb":
                 f"{payload['snapshot']['snapshot_bytes'] / 1e6:.2f}"})

    emit(rows, "exp12")
    emit_json(payload, "exp12", out_dir)
    return rows


if __name__ == "__main__":
    run()
