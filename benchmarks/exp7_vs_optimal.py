"""Exp-7 — distance to the optimal approach (one index per query key).

ELI-0.5 ~ optimal QPS at a fraction of its space; ELI-2.0 trades QPS for
a hard 2x space budget."""
from repro.baselines import BASELINE_REGISTRY
from repro.core.engine import LabelHybridEngine

from .common import emit, ground_truth, make_dataset, measure


def run(n=6_000, k=10, L=16):
    x, ls, qv, qls = make_dataset(n=n, n_labels=L, q=120)
    gt_d, gt_i = ground_truth(x, ls, qv, qls, k)
    rows = []
    systems = [
        ("optimal", BASELINE_REGISTRY["optimal"](x, ls), None),
        ("ELI-0.5", LabelHybridEngine.build(x, ls, mode="eis", c=0.5,
                                            backend="flat"), None),
        ("ELI-0.2", LabelHybridEngine.build(x, ls, mode="eis", c=0.2,
                                            backend="flat"), None),
        ("ELI-2.0", LabelHybridEngine.build(x, ls, mode="sis",
                                            space_budget=2 * n,
                                            backend="flat"), None),
    ]
    for name, s, _ in systems:
        qps, rec, us = measure(s, qv, qls, k, gt_i, n)
        size = (s.stats().total_entries if hasattr(s, "stats")
                else getattr(s, "total_entries", -1))
        rows.append({"name": f"exp7/{name}", "us_per_call": f"{us:.1f}",
                     "qps": f"{qps:.0f}", "recall": f"{rec:.4f}",
                     "entries": size})
    emit(rows, "exp7")
    return rows


if __name__ == "__main__":
    run()
