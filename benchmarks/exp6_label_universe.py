"""Exp-6 — large label universes |L| (paper: 64..512; here 32..128 on one
core).  ELI's fixed-efficiency selection stays flat; UNG's cross-group
machinery degrades with |L|."""
import time

from repro.baselines import BASELINE_REGISTRY
from repro.core.engine import LabelHybridEngine

from .common import emit, ground_truth, make_dataset, measure


def run(n=5_000, k=10, sizes=(32, 64, 128)):
    rows = []
    for L in sizes:
        x, ls, qv, qls = make_dataset(n=n, n_labels=L, q=80)
        gt_d, gt_i = ground_truth(x, ls, qv, qls, k)
        t0 = time.perf_counter()
        eng = LabelHybridEngine.build(x, ls, mode="eis", c=0.2,
                                      backend="flat")
        eli_build = time.perf_counter() - t0
        t0 = time.perf_counter()
        ung = BASELINE_REGISTRY["ung"](x, ls)
        ung_build = time.perf_counter() - t0
        for name, s, bt in (("ELI-0.2", eng, eli_build),
                            ("ung", ung, ung_build)):
            qps, rec, us = measure(s, qv, qls, k, gt_i, n)
            rows.append({"name": f"exp6/L={L}/{name}",
                         "us_per_call": f"{us:.1f}", "qps": f"{qps:.0f}",
                         "recall": f"{rec:.4f}", "build_s": f"{bt:.2f}"})
    emit(rows, "exp6")
    return rows


if __name__ == "__main__":
    run()
