"""Exp-3 — thread scaling becomes shard scaling on the TPU mesh.

Runs in a subprocess with 8 fake host devices; the DistributedFlatIndex
shards rows over the 'data' axis and merges per-shard top-k with one
all-gather.  Reported: per-shard-count QPS + recall (merge correctness) +
the collective payload (2·S·k·8 bytes per query — N-independent).
"""
import json
import subprocess
import sys

from .common import emit

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import numpy as np
import jax
from benchmarks.common import make_dataset, ground_truth, measure
from repro.core.labels import encode_many, masks_to_int32_words
from repro.index.distributed import DistributedFlatIndex

x, ls, qv, qls = make_dataset(n=16_000, q=96)
gt_d, gt_i = ground_truth(x, ls, qv, qls, 10)
words = masks_to_int32_words(encode_many(ls))


class W:
    def __init__(self, ix):
        self.ix = ix

    def search(self, qv, qls, k):
        return self.ix.search(qv, masks_to_int32_words(encode_many(qls)), k)


out = []
for s in (1, 2, 4, 8):
    mesh = jax.make_mesh((s,), ("data",), devices=jax.devices()[:s])
    ix = DistributedFlatIndex(x, words, mesh)
    qps, rec, us = measure(W(ix), qv, qls, 10, gt_i, len(ls))
    out.append({"shards": s, "qps": round(qps), "recall": round(rec, 4),
                "us": round(us, 1),
                "collective_bytes_per_q": 2 * s * 10 * 8})
print("RESULT" + json.dumps(out))
"""


def run():
    r = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                       text=True, env=None, cwd=".")
    line = next((ln for ln in r.stdout.splitlines() if ln.startswith("RESULT")),
                None)
    if line is None:
        print(r.stdout[-2000:], r.stderr[-2000:])
        raise RuntimeError("exp3 child failed")
    rows = []
    for rec in json.loads(line[len("RESULT"):]):
        rows.append({"name": f"exp3/shards={rec['shards']}",
                     "us_per_call": rec["us"], "qps": rec["qps"],
                     "recall": rec["recall"],
                     "collective_bytes_per_q": rec["collective_bytes_per_q"]})
    emit(rows, "exp3")
    return rows


if __name__ == "__main__":
    run()
